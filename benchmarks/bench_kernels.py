"""Kernel microbenchmarks (CPU wall time of the jnp paths + interpret-mode
checks; BlockSpec sweeps report the tiling chosen for TPU).

Run as a module (``python -m benchmarks.bench_kernels --out
BENCH_kernels.json``) to also write the serving-kernel roofline report:
predicted fused-vs-unfused HBM bytes/token for ``kernels.paged_attn`` and
``kernels.moe_dequant`` (the analytic models in ``roofline.analysis``) next
to the bytes the *current* lowering actually compiles to, plus a tripwire
that fails if any fused kernel stops predicting <= 0.5x the unfused
gather+dequant traffic."""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import obs as obs_mod
from repro import utils
from repro.core import hessian as hess
from repro.core import qformat
from repro.kernels.dequant_matmul import ops as dq_ops
from repro.kernels.hessian_gg import ops as gg_ops
from repro.kernels.moe_dequant import ops as moe_ops
from repro.kernels.paged_attn import ops as pa_ops
from repro.roofline import analysis


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        utils.block_all(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_dequant(ctx=None):
    rng = np.random.default_rng(0)
    for (M, K, N, bits) in [(64, 1024, 1024, 2), (64, 1024, 1024, 4),
                            (8, 2048, 2048, 2)]:
        gs = 64
        codes = jnp.asarray(rng.integers(0, 2 ** bits, (K, N)), jnp.uint8)
        from repro.core import quantizers as qz
        W = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
        q, s, z, _ = qz.rtn_quantize(W, bits, gs)
        cap = 8
        zr = jnp.zeros(cap, jnp.int32)
        qt = qformat.make_quantized(q, s, z, bits, gs, (K, N), zr, zr,
                                    jnp.zeros(cap, jnp.bfloat16))
        x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
        f = jax.jit(lambda xx: dq_ops.dequant_matmul(xx, qt))
        us = _time(f, x)
        dense = jax.jit(lambda xx: xx @ W)
        us_d = _time(dense, x)
        common.emit(f"kernels/dequant_matmul_M{M}_K{K}_N{N}_w{bits}", us,
                    f"dense_us={us_d:.0f};packed_bytes={sum(p.size for p in qt.planes)}")


def bench_hessian_gg(ctx=None):
    rng = np.random.default_rng(1)
    for (D, dout) in [(512, 512), (1024, 512)]:
        G = jnp.asarray(rng.normal(size=(D, dout)).astype(np.float32))
        f = jax.jit(lambda g: gg_ops.gg_update(g))
        us = _time(f, G)
        tri_flops = D * (D + 1) / 2 * dout * 2
        full_flops = D * D * dout * 2
        common.emit(f"kernels/hessian_gg_D{D}_dout{dout}", us,
                    f"tri_flop_saving={full_flops / tri_flops:.2f}x")


def bench_calib_blocks(ctx=None):
    rng = np.random.default_rng(2)
    from repro.core import solver
    for (d_in, d_out) in [(512, 512), (1024, 1024)]:
        W = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32))
        X = jnp.asarray(rng.normal(size=(512, d_in)).astype(np.float32))
        H = X.T @ X
        f = jax.jit(lambda w, h: solver.calibrate(
            w, h, bits=2, group_size=64, alpha=0.1, tau=3.5,
            outlier_capacity=0.005).w_hat)
        us = _time(f, W, H, reps=2)
        common.emit(f"kernels/solver_calibrate_{d_in}x{d_out}_w2", us,
                    f"cols_per_s={d_in / (us / 1e6):.0f}")


def paged_attn_report(registry=None):
    """Timing + bytes/token for the paged decode: bounded vs full tables,
    predicted fused-vs-unfused traffic (fp16 and int8 KV), achieved bytes
    of the compiled fallback lowering.  Achieved bytes are measured via
    ``analysis.record_achieved_bytes`` so the report rows and the
    ``kernel_achieved_bytes{kernel=...}`` gauge family of ``registry``
    share one measurement."""
    registry = registry or obs_mod.MetricsRegistry()
    from repro.serving.qserve import kvquant as KQ
    rng = np.random.default_rng(3)
    B, bs, live, mb, KV, H, Dh = 4, 16, 8, 32, 4, 8, 64
    nb = B * live + 1                        # block 0 reserved scratch
    kp = jnp.asarray(rng.normal(size=(nb, bs, KV, Dh)), jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(nb, bs, KV, Dh)), jnp.bfloat16)
    tbl = np.full((B, mb), -1, np.int32)
    tbl[:, :live] = 1 + np.arange(B * live).reshape(B, live)
    bt_full, bt_live = jnp.asarray(tbl), jnp.asarray(tbl[:, :live])
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.bfloat16)
    pos = jnp.full((B,), live * bs - 1, jnp.int32)
    kq, ks = KQ.quantize_kv(kp)
    vq, vs = KQ.quantize_kv(vp)

    def fp(qq, bt):
        return pa_ops.paged_decode(qq, kp, vp, bt, pos)

    def i8(qq, bt):
        return pa_ops.paged_decode(qq, kq, vq, bt, pos,
                                   k_scale=ks, v_scale=vs)

    us_full = _time(jax.jit(fp), q, bt_full)
    us_live = _time(jax.jit(fp), q, bt_live)
    o_ref = fp(q, bt_live)
    o_k = pa_ops.paged_decode(q, kp, vp, bt_live, pos,
                              force_kernel=True, interpret=True)
    parity = float(jnp.max(jnp.abs(o_k.astype(jnp.float32)
                                   - o_ref.astype(jnp.float32))))
    return {
        "geom": {"B": B, "block_size": bs, "live_blocks": live,
                 "max_blocks": mb, "n_kv": KV, "n_heads": H, "d_head": Dh},
        "us_fallback_full_table": us_full,
        "us_fallback_live_table": us_live,
        "kernel_interpret_max_abs_diff": parity,
        "predicted_bytes_per_token": {
            "fp16": analysis.paged_attn_bytes(1, live, bs, KV, Dh, H, 16),
            "int8": analysis.paged_attn_bytes(1, live, bs, KV, Dh, H, 8)},
        "achieved_bytes_per_token": {
            "fallback_full_table": analysis.record_achieved_bytes(
                registry, "paged_attn/fallback_full_table",
                fp, q, bt_full) / B,
            "fallback_live_table": analysis.record_achieved_bytes(
                registry, "paged_attn/fallback_live_table",
                fp, q, bt_live) / B,
            "fallback_live_table_int8": analysis.record_achieved_bytes(
                registry, "paged_attn/fallback_live_table_int8",
                i8, q, bt_live) / B},
    }


def moe_dequant_report(registry=None):
    """Timing + bytes for the stacked-expert contraction: per-expert scan
    over the compacted routed set vs the dense all-experts reconstruction.
    Achieved bytes land in ``registry``'s ``kernel_achieved_bytes`` gauges
    (see ``paged_attn_report``)."""
    registry = registry or obs_mod.MetricsRegistry()
    from repro.configs.base import QuantConfig
    from repro.kernels.moe_dequant.ref import moe_dequant_matmul_ref
    from repro.serving.quantized import _quantize_leaf
    rng = np.random.default_rng(4)
    E, Er, T, K, N, bits, gs = 8, 4, 16, 256, 256, 4, 64
    W = jnp.asarray(rng.normal(size=(E, K, N)).astype(np.float32))
    qt = _quantize_leaf(W, QuantConfig(wbits=bits, group_size=gs,
                                       method="rtn"))
    xe = jnp.asarray(rng.normal(size=(E, T, K)), jnp.bfloat16)
    eidx = jnp.arange(Er, dtype=jnp.int32)
    qt_r = jax.tree.map(lambda a: a[eidx], qt)
    xe_r = xe[:Er]

    def routed(x):
        return moe_ops.moe_dequant_matmul(x, qt_r)

    def dense(x):
        return moe_dequant_matmul_ref(x, qt)

    us_routed = _time(jax.jit(routed), xe_r)
    us_dense = _time(jax.jit(dense), xe)
    y_k = moe_ops.moe_dequant_matmul(xe_r, qt_r, force_kernel=True,
                                     interpret=True)
    parity = float(jnp.max(jnp.abs(y_k.astype(jnp.float32)
                                   - routed(xe_r).astype(jnp.float32))))
    return {
        "geom": {"n_experts": E, "n_routed": Er, "T": T, "K": K, "N": N,
                 "bits": bits, "group_size": gs},
        "us_scan_routed": us_routed,
        "us_dense_all_experts": us_dense,
        "kernel_interpret_max_abs_diff": parity,
        "predicted_bytes": analysis.moe_dequant_bytes(Er, E, T, K, N,
                                                      bits, gs),
        "achieved_bytes": {
            "scan_routed": analysis.record_achieved_bytes(
                registry, "moe_dequant/scan_routed", routed, xe_r),
            "dense_all_experts": analysis.record_achieved_bytes(
                registry, "moe_dequant/dense_all_experts", dense, xe)},
    }


def bench_paged_attn(ctx=None):
    r = paged_attn_report()
    pred = r["predicted_bytes_per_token"]
    common.emit(
        "kernels/paged_attn_decode_B4_live8_mb32",
        r["us_fallback_live_table"],
        f"full_table_us={r['us_fallback_full_table']:.0f};"
        f"pred_fused_ratio_fp16={pred['fp16']['ratio']:.3f};"
        f"pred_fused_ratio_int8={pred['int8']['ratio']:.3f};"
        f"interp_diff={r['kernel_interpret_max_abs_diff']:.2e}")


def bench_moe_dequant(ctx=None):
    r = moe_dequant_report()
    common.emit(
        "kernels/moe_dequant_E8_routed4_w4",
        r["us_scan_routed"],
        f"dense_us={r['us_dense_all_experts']:.0f};"
        f"pred_fused_ratio={r['predicted_bytes']['ratio']:.3f};"
        f"interp_diff={r['kernel_interpret_max_abs_diff']:.2e}")


ALL = [bench_dequant, bench_hessian_gg, bench_calib_blocks,
       bench_paged_attn, bench_moe_dequant]

TRIPWIRE_RATIO = 0.5   # fused kernels must predict <= half the unfused bytes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the serving-kernel roofline report (JSON)")
    ap.add_argument("--metrics-out", default=None, metavar="metrics.prom",
                    help="write the kernel_achieved_bytes gauges as "
                         "Prometheus text exposition")
    args = ap.parse_args(argv)
    reg = obs_mod.MetricsRegistry()
    pa = paged_attn_report(reg)
    moe = moe_dequant_report(reg)
    ratios = {
        "paged_attn_fp16": pa["predicted_bytes_per_token"]["fp16"]["ratio"],
        "paged_attn_int8": pa["predicted_bytes_per_token"]["int8"]["ratio"],
        "moe_dequant_w4": moe["predicted_bytes"]["ratio"],
    }
    ok = all(r <= TRIPWIRE_RATIO for r in ratios.values())
    report = {"paged_attn": pa, "moe_dequant": moe,
              "tripwire": {"max_ratio": TRIPWIRE_RATIO, "ratios": ratios,
                           "pass": ok}}
    for k, v in ratios.items():
        print(f"kernels/bytes_ratio/{k},{v:.4f},"
              f"{'OK' if v <= TRIPWIRE_RATIO else 'TRIP'}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"# wrote {args.out}")
    if args.metrics_out:
        obs_mod.prom.write(args.metrics_out, reg)
        print(f"# metrics -> {args.metrics_out}")
    if not ok:
        print("# roofline tripwire: fused kernel predicts > "
              f"{TRIPWIRE_RATIO}x unfused bytes", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
