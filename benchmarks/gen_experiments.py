"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cells(mesh, suffix=""):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, f"*__{mesh}{suffix}.json"))):
        r = json.load(open(p))
        if bool(r.get("quantized")) != bool(suffix):
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def dryrun_table():
    single = cells("16x16")
    multi = cells("2x16x16")
    lines = ["| arch | shape | attn (train/decode) | args GiB/dev | "
             "temp GiB/dev | compile s | multi-pod |",
             "|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(single.items(),
                            key=lambda kv: (kv[0][0],
                                            SHAPE_ORDER.index(kv[0][1]))):
        m = r["memory"]
        mp = "OK" if (a, s) in multi else "-"
        lines.append(
            f"| {a} | {s} | {r['attn_modes'][0]}/{r['attn_modes'][1]} | "
            f"{m.get('argument_size_in_bytes', 0) / 2**30:.2f} | "
            f"{m.get('temp_size_in_bytes', 0) / 2**30:.2f} | "
            f"{r['compile_s']:.0f} | {mp} |")
    return "\n".join(lines)


def roofline_table():
    single = cells("16x16")
    lines = ["| arch | shape | t_compute s | t_memory s | t_collective s | "
             "bottleneck | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(single.items(),
                            key=lambda kv: (kv[0][0],
                                            SHAPE_ORDER.index(kv[0][1]))):
        f = r["roofline"]
        lines.append(
            f"| {a} | {s} | {f['t_compute_s']:.4f} | {f['t_memory_s']:.4f} |"
            f" {f['t_collective_s']:.4f} | {f['bottleneck']} |"
            f" {f['useful_ratio']:.3f} | {f['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def quantized_rows():
    lines = []
    for p in sorted(glob.glob(os.path.join(ART, "*__16x16__w2.json"))):
        r = json.load(open(p))
        f = r["roofline"]
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} w2 | {f['t_compute_s']:.4f} | "
            f"{f['t_memory_s']:.4f} | {f['t_collective_s']:.4f} | "
            f"{f['bottleneck']} | args {m.get('argument_size_in_bytes', 0) / 2**30:.1f} GiB |")
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!--DRYRUN_TABLE-->", dryrun_table())
    text = text.replace("<!--ROOFLINE_TABLE-->", roofline_table())
    text = text.replace("<!--W2_ROWS-->", quantized_rows())
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables generated")


if __name__ == "__main__":
    main()
